"""Trainer fault-tolerance + RangeServer behaviour tests."""
import functools
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BuildConfig, RangeConfig, RangeSearchEngine, SearchConfig,
    average_precision, build_knn_graph, build_vamana, exact_range_search,
)
from repro.data.lm import LMDataConfig, lm_batches
from repro.models import TransformerConfig, init_transformer, loss_fn
from repro.optim import AdamWConfig
from repro.serve import RangeServer, Request, ServerConfig
from repro.train import CheckpointManager, Trainer, TrainerConfig

CFG = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv=1,
                        d_head=16, d_ff=64, vocab=64, dtype=jnp.float32,
                        loss_chunk=16, remat=False)
DCFG = LMDataConfig(vocab=64, seq_len=16, batch=4)
LOSS = functools.partial(loss_fn, cfg=CFG)


def _trainer(tmp, total=20, **kw):
    return Trainer(LOSS, init_transformer(jax.random.PRNGKey(0), CFG),
                   AdamWConfig(lr=1e-2, total_steps=100, warmup_steps=2),
                   TrainerConfig(total_steps=total, ckpt_every=10,
                                 log_every=5, ckpt_dir=str(tmp), **kw))


def test_loss_decreases_and_metrics_logged(tmp_path):
    mpath = str(tmp_path / "metrics.jsonl")
    tr = _trainer(tmp_path / "ck", metrics_path=mpath)
    out = tr.fit(lm_batches(DCFG))
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]
    lines = [json.loads(l) for l in open(mpath)]
    assert len(lines) >= 3 and all("loss" in l for l in lines)


def test_checkpoint_atomicity_and_keep_k(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, {"a": jnp.ones((3,)) * s})
    assert cm.completed_steps() == [3, 4]
    # a stale tmp dir is ignored
    os.makedirs(str(tmp_path / "step_0000000099.tmp"))
    assert cm.latest_step() == 4
    state, step = cm.restore({"a": jnp.zeros((3,))})
    assert step == 4 and float(state["a"][0]) == 4.0


def test_restart_resumes_exactly(tmp_path):
    ck = tmp_path / "ck"
    tr1 = _trainer(ck, total=20)
    tr1.fit(lm_batches(DCFG))
    p1 = jax.tree.leaves(tr1.params)[0]

    tr2 = _trainer(ck, total=30)
    assert tr2.maybe_restore()
    assert tr2.step == 20
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(tr2.params)[0]),
                                  np.asarray(p1))
    out = tr2.fit(lm_batches(DCFG, start_step=20))
    assert out["final_step"] == 30


def test_data_fault_skipped_not_fatal(tmp_path):
    class Flaky:
        """Retryable loader: one transient failure, then recovers (a plain
        generator would die permanently — real loaders retry)."""

        def __init__(self):
            self.src = lm_batches(DCFG)
            self.n = 0

        def __iter__(self):
            return self

        def __next__(self):
            self.n += 1
            if self.n == 3:
                raise RuntimeError("simulated data-shard timeout")
            return next(self.src)

    tr = _trainer(tmp_path / "ck", total=10)
    out = tr.fit(Flaky())
    assert out["final_step"] == 10  # the fault was absorbed


def test_preemption_signal_checkpoints(tmp_path):
    tr = _trainer(tmp_path / "ck", total=1000)

    src = lm_batches(DCFG)

    def batches():
        n = 0
        while True:
            n += 1
            if n == 6:  # simulate SIGTERM mid-run
                os.kill(os.getpid(), signal.SIGTERM)
            yield next(src)

    out = tr.fit(batches())
    assert out["final_step"] < 1000
    cm = CheckpointManager(str(tmp_path / "ck"))
    assert cm.latest_step() == out["final_step"]  # preemption checkpoint


def test_gradient_accumulation_matches_big_batch(tmp_path):
    params = init_transformer(jax.random.PRNGKey(0), CFG)
    from repro.optim import init_adamw, make_train_step
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1, schedule="constant")
    big = make_train_step(LOSS, opt_cfg)
    acc = make_train_step(LOSS, opt_cfg, accum_steps=2)
    batch = next(lm_batches(LMDataConfig(vocab=64, seq_len=16, batch=8)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    p1, _, m1 = big(params, init_adamw(params, opt_cfg), batch)
    p2, _, m2 = acc(params, init_adamw(params, opt_cfg), batch)
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# RangeServer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_engine():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.standard_normal((1500, 12)), jnp.float32)
    eng = RangeSearchEngine.from_graph(pts, build_knn_graph(pts, k=10))
    return pts, eng


def test_server_end_to_end_ap(small_engine):
    pts, eng = small_engine
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128),
                      mode="greedy", result_cap=256)
    srv = RangeServer(eng, cfg, ServerConfig(max_batch=32))
    qs = np.asarray(pts[:60]) + 0.01
    for i in range(60):
        srv.submit(Request(req_id=i, query=qs[i], radius=4.0))
    resp = srv.run_until_drained()
    assert len(resp) == 60 and srv.pending() == 0
    assert srv.stats["batches"] >= 2  # micro-batching happened
    gt = exact_range_search(pts, jnp.asarray(qs), 4.0)
    ids = np.full((60, 256), 2**31 - 1, np.int64)
    counts = np.zeros(60, np.int64)
    for r in resp:
        ids[r.req_id, :len(r.ids)] = r.ids
        counts[r.req_id] = len(r.ids)
    ap = average_precision(np.asarray(gt[0]), np.asarray(gt[2]), ids, counts)
    assert ap > 0.8


@pytest.fixture(scope="module")
def clustered_engine():
    """Well-navigable Vamana index on clustered data: greedy range search
    recovers exact in-range sets here, so per-radius oracle equality is a
    meaningful (non-flaky) server assertion."""
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((8, 12)).astype(np.float32) * 3
    pts = jnp.asarray(centers[rng.integers(0, 8, 1200)] +
                      rng.standard_normal((1200, 12)).astype(np.float32) * 0.4)
    g = build_vamana(pts, BuildConfig(max_degree=24, beam=48, insert_batch=256,
                                      two_pass=True))
    return pts, RangeSearchEngine.from_graph(pts, g)


def test_server_mixed_radius_batch_per_request_ground_truth(clustered_engine):
    """Regression test for the batch radius coercion bug: the server used to
    apply ``reqs[0].radius`` to the whole micro-batch, silently answering
    every other request at the first one's radius. Two requests with radii
    (r_small, r_large) in ONE batch must each get exactly their own
    radius's oracle set."""
    pts, eng = clustered_engine
    q = np.asarray(pts[0]) + 0.01
    r_small, r_large = 1.0, 8.0
    cfg = RangeConfig(search=SearchConfig(beam=64, max_beam=64, visit_cap=256),
                      mode="greedy", result_cap=512)
    srv = RangeServer(eng, cfg, ServerConfig(max_batch=32))
    srv.submit(Request(req_id=0, query=q, radius=r_small))
    srv.submit(Request(req_id=1, query=q, radius=r_large))
    resp = sorted(srv.run_until_drained(), key=lambda x: x.req_id)
    assert srv.stats["batches"] == 1  # both served from ONE micro-batch
    assert srv.stats["mixed_radius_batches"] == 1

    gt = {}
    for r in (r_small, r_large):
        ids, _, counts = exact_range_search(pts, jnp.asarray(q)[None], r)
        gt[r] = set(np.asarray(ids)[0][: int(counts[0])].tolist())
    assert gt[r_small] < gt[r_large]  # radii chosen to answer differently

    assert resp[0].radius == r_small and resp[1].radius == r_large
    assert set(resp[0].ids.tolist()) == gt[r_small]
    assert set(resp[1].ids.tolist()) == gt[r_large]
    # per-request dists honor the request's own radius
    assert len(resp[0].ids) and resp[0].dists.max() <= r_small + 1e-5
    assert len(resp[1].ids) and resp[1].dists.max() <= r_large + 1e-5
    assert resp[1].dists.max() > r_small  # large lane really used its radius


def test_server_results_sorted_and_deduped(small_engine):
    pts, eng = small_engine
    cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16, visit_cap=64),
                      mode="greedy", result_cap=128)
    srv = RangeServer(eng, cfg)
    srv.submit(Request(req_id=0, query=np.asarray(pts[0]), radius=4.0))
    (resp,) = srv.run_until_drained()
    assert len(np.unique(resp.ids)) == len(resp.ids)
    assert resp.count == len(resp.ids) or resp.overflow


def test_server_bounded_admission_queue(small_engine):
    """Admission is bounded: beyond max_queue, submit sheds the request with
    a structured ``Response(op="error", code="queue_full")`` (None means
    admitted) instead of growing the deque without limit — and the shed is
    DELIVERED, not silently dropped, so callers can retry under
    backpressure."""
    pts, eng = small_engine
    cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16, visit_cap=64),
                      mode="greedy", result_cap=128)
    srv = RangeServer(eng, cfg, ServerConfig(max_batch=8, max_queue=4))
    outcome = [srv.submit(Request(req_id=i, query=np.asarray(pts[i]),
                                  radius=1.0)) for i in range(7)]
    assert outcome[:4] == [None] * 4  # admitted
    for i, rej in enumerate(outcome[4:], start=4):
        assert rej.op == "error" and rej.code == "queue_full"
        assert rej.req_id == i and not rej.complete and rej.coverage == 0.0
        assert len(rej.ids) == 0
    assert srv.pending() == 4 and srv.stats["rejected"] == 3
    resp = srv.run_until_drained()
    assert sorted(r.req_id for r in resp) == [0, 1, 2, 3]  # shed ones never served
    assert srv.submit(Request(req_id=9, query=np.asarray(pts[0]),
                              radius=1.0)) is None  # drained -> admitting again


def test_server_live_mutation_requests(clustered_engine):
    """insert/delete requests ride the same admission queue as queries; the
    batch's mutations apply first, then its queries are answered against
    ONE consistent epoch snapshot (fresh point found at its exact distance,
    deleted point never returned)."""
    from repro.live import LiveConfig, LiveIndex
    pts, eng = clustered_engine
    live = LiveIndex.create(pts, LiveConfig(capacity=1500, insert_batch=64),
                            BuildConfig(max_degree=24, beam=48,
                                        insert_batch=256, two_pass=True),
                            graph=eng.graph)
    cfg = RangeConfig(search=SearchConfig(beam=64, max_beam=64, visit_cap=256),
                      mode="greedy", result_cap=512)
    srv = RangeServer(None, cfg, ServerConfig(max_batch=16), live=live)
    with pytest.raises(ValueError, match="live"):
        RangeServer(eng, cfg).submit(Request(req_id=0, op="delete",
                                             delete_ids=np.asarray([1])))
    fresh = np.asarray(pts[0]) * 0.5 + 3.0
    srv.submit(Request(req_id=0, op="insert", query=fresh))
    srv.submit(Request(req_id=1, op="delete",
                       delete_ids=np.asarray([3, 4, 4])))
    srv.submit(Request(req_id=2, query=fresh + 0.001, radius=1.0))
    srv.submit(Request(req_id=3, query=np.asarray(pts[3]), radius=1.0))
    resp = {r.req_id: r for r in srv.run_until_drained()}
    assert len(resp) == 4
    new_id = int(resp[0].ids[0])
    assert new_id == 1200 and resp[0].op == "insert"
    assert resp[1].op == "delete" and srv.stats["deletes"] == 2
    # the SAME batch's query sees the insert at its exact distance...
    assert new_id in resp[2].ids.tolist()
    j = resp[2].ids.tolist().index(new_id)
    np.testing.assert_allclose(resp[2].dists[j],
                               float(np.sum((fresh + 0.001 - fresh) ** 2)),
                               atol=1e-5)
    # ...and never the tombstoned points
    assert not ({3, 4} & set(resp[3].ids.tolist()))
    assert resp[2].epoch == resp[3].epoch == live.epoch  # one snapshot
    assert srv.stats["inserts"] == 1 and srv.stats["epoch"] == live.epoch


def test_server_corpus_dtype_contract(small_engine):
    """SearchConfig.corpus_dtype must match what the served corpus stores
    (the declarative knob is validated at the serving boundary), and an
    int8 engine surfaces the guard-band rerank counter in server stats."""
    pts, eng = small_engine
    cfg_i8 = RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                             visit_cap=64,
                                             corpus_dtype="int8"),
                         mode="greedy", result_cap=128)
    with pytest.raises(ValueError, match="corpus_dtype"):
        RangeServer(eng, cfg_i8)  # f32 engine behind an int8 config
    eng_i8 = RangeSearchEngine.from_graph(pts, eng.graph,
                                          corpus_dtype="int8")
    srv = RangeServer(eng_i8, cfg_i8)
    for i in range(8):
        srv.submit(Request(req_id=i, query=np.asarray(pts[i]) + 0.01,
                           radius=4.0))
    resp = srv.run_until_drained()
    assert len(resp) == 8
    assert srv.stats["reranked"] >= 0
    d2 = np.sum((np.asarray(pts)[None] - np.stack(
        [np.asarray(pts[i]) + 0.01 for i in range(8)])[:, None]) ** 2, axis=-1)
    for r in resp:  # post-rerank: exactly-in-range only
        assert np.all(d2[r.req_id, r.ids] <= 4.0 + 1e-5)


# ---------------------------------------------------------------------------
# continuous batching (lane pool)
# ---------------------------------------------------------------------------

_POOL_CFG = dict(max_batch=8, continuous=True, lanes=4, slice_rounds=1)


def _drain_ids(srv, reqs):
    for r in reqs:
        srv.submit(r)
    return srv.run_until_drained()


def test_server_continuous_straggler_rotation(clustered_engine):
    """A straggler lane parked in the pool must not perturb point queries:
    the continuous scheduler rotates past it (pool_rotations > 0), and the
    point queries' results AND their per-request-id response order are
    identical to a run without the straggler."""
    pts, eng = clustered_engine
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32,
                                          visit_cap=256),
                      mode="greedy", result_cap=512)
    qs = np.asarray(pts[:16]) + 0.01
    point = [Request(req_id=i, query=qs[i], radius=0.5) for i in range(16)]
    straggler = Request(req_id=99, query=np.asarray(pts[40]), radius=9.0)

    srv_a = RangeServer(eng, cfg, ServerConfig(**_POOL_CFG))
    resp_a = _drain_ids(srv_a, [straggler] + point)
    srv_b = RangeServer(eng, cfg, ServerConfig(**_POOL_CFG))
    resp_b = _drain_ids(srv_b, point)

    # the straggler really did straggle: slice_rounds=1 makes its lane
    # survive ticks while point traffic keeps flowing around it
    assert srv_a.stats["pool_admitted"] >= 1
    assert srv_a.stats["pool_rotations"] >= 1
    assert len(resp_a) == 17 and len(resp_b) == 16

    a = {r.req_id: r for r in resp_a}
    b = {r.req_id: r for r in resp_b}
    assert len(a[99].ids) >= 32  # the straggler saturated its beam
    for i in range(16):
        np.testing.assert_array_equal(a[i].ids, b[i].ids, err_msg=f"req {i}")
        np.testing.assert_array_equal(a[i].dists, b[i].dists)
        assert a[i].count == b[i].count
    # per-request-id response order of the point queries is unchanged
    order_a = [r.req_id for r in resp_a if r.req_id != 99]
    order_b = [r.req_id for r in resp_b]
    assert order_a == order_b


def test_server_continuous_matches_lockstep(clustered_engine):
    """Continuous batching is a latency optimization, not a semantics
    change: per-request id sets, counts, and overflow flags are identical
    to the lockstep server on a mixed-radius workload."""
    pts, eng = clustered_engine
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32,
                                          visit_cap=256),
                      mode="greedy", result_cap=512)
    qs = np.asarray(pts[:24]) + 0.01
    radii = np.where(np.arange(24) % 3 == 0, 9.0, 0.5).astype(np.float32)
    reqs = lambda: [Request(req_id=i, query=qs[i], radius=float(radii[i]))
                    for i in range(24)]

    lock = RangeServer(eng, cfg, ServerConfig(max_batch=8))
    cont = RangeServer(eng, cfg, ServerConfig(**_POOL_CFG))
    rl = {r.req_id: r for r in _drain_ids(lock, reqs())}
    rc = {r.req_id: r for r in _drain_ids(cont, reqs())}
    assert cont.stats["pool_admitted"] > 0  # the pool actually ran
    for i in range(24):
        assert frozenset(rl[i].ids.tolist()) == frozenset(rc[i].ids.tolist())
        assert rl[i].count == rc[i].count
        assert rl[i].overflow == rc[i].overflow
    # both latency surfaces populated: end-to-end and service histograms
    summ = cont.latency_summary()
    assert summ["all"]["count"] == 24 and summ["service"]["count"] == 24
    assert summ["all"]["p99_ms"] >= summ["all"]["p50_ms"] > 0
    for r in rc.values():
        assert set(r.timings) == {"queue_s", "service_s", "total_s"}
        assert r.timings["total_s"] >= r.timings["service_s"] >= 0


# ---------------------------------------------------------------------------
# unified public API: retired aliases + deploy-config overrides
# ---------------------------------------------------------------------------

def test_retired_aliases_rejected(small_engine):
    """The PR-6 deprecation aliases are retired: op="query" and the
    positional/points= spellings now fail loudly instead of warning."""
    from repro.core import range_search_fused
    pts, eng = small_engine
    qs = jnp.asarray(np.asarray(pts[:4]) + 0.01)
    cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                          visit_cap=64),
                      mode="greedy", result_cap=128)
    with pytest.raises(ValueError, match="unknown op"):
        RangeServer(eng, cfg).submit(
            Request(req_id=0, op="query", query=np.zeros(4, np.float32),
                    radius=1.0))
    with pytest.raises(TypeError):
        eng.range(qs, 4.0, cfg)  # cfg is keyword-only now
    with pytest.raises(TypeError):
        range_search_fused(points=pts, graph=eng.graph, queries=qs,
                           start_ids=eng.start_ids, r=4.0, cfg=cfg)


def test_deprecated_server_config_expand_width():
    with pytest.warns(DeprecationWarning, match="expand_width"):
        ServerConfig(expand_width=4)


def test_engine_deploy_config_overrides_routing():
    """overrides() routes each knob to the level that owns it and rejects
    unknown names instead of silently no-opping."""
    from repro.configs.range_engine import EngineDeployConfig
    base = EngineDeployConfig()
    out = base.overrides(beam=8, max_beam=8,        # -> SearchConfig
                         result_cap=64, lam=0.5,    # -> RangeConfig
                         dim=64, metric="ip")       # -> deploy level
    assert out.range_cfg.search.beam == 8
    assert out.range_cfg.search.max_beam == 8
    assert out.range_cfg.result_cap == 64
    assert out.range_cfg.lam == 0.5
    assert out.dim == 64
    # cross-level contracts propagate both ways
    assert out.metric == "ip" and out.range_cfg.search.metric == "ip"
    i8 = base.overrides(corpus_dtype="int8")
    assert i8.corpus_dtype == "int8"
    assert i8.range_cfg.search.corpus_dtype == "int8"
    # untouched knobs untouched; the base config is never mutated
    assert out.range_cfg.search.visit_cap == base.range_cfg.search.visit_cap
    assert base.range_cfg.search.beam == 64
    with pytest.raises(TypeError, match="unknown knob"):
        base.overrides(beamwidth=8)
