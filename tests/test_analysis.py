"""HLO analysis (trip-count-aware FLOPs/collectives) + roofline math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_module
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS


def test_scan_trip_count_flops():
    w = jnp.zeros((128, 128), jnp.float32)
    x = jnp.zeros((32, 128), jnp.float32)

    def scanned(x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    a = analyze_module(jax.jit(scanned).lower(x).compile().as_text())
    assert a.n_while == 1 and a.max_trip == 7
    np.testing.assert_allclose(a.dot_flops, 2 * 32 * 128 * 128 * 7)


def test_nested_scan_multiplies():
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    a = analyze_module(jax.jit(nested).lower(x).compile().as_text())
    np.testing.assert_allclose(a.dot_flops, 2 * 8 * 64 * 64 * 12)


def test_plain_matmul_flops_and_bytes():
    w = jnp.zeros((256, 256), jnp.float32)
    x = jnp.zeros((64, 256), jnp.float32)
    a = analyze_module(jax.jit(lambda x: x @ w).lower(x).compile().as_text())
    np.testing.assert_allclose(a.dot_flops, 2 * 64 * 256 * 256)
    # traffic at least inputs+outputs once
    assert a.hbm_bytes >= 4 * (64 * 256 + 256 * 256 + 64 * 256) * 0.9


def test_collectives_parsed_with_group_size():
    import subprocess, sys, os, textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo import analyze_module
        mesh = jax.make_mesh((8,), ("d",))
        s = NamedSharding(mesh, P("d"))
        f = jax.jit(lambda x: jnp.sum(x), in_shardings=(s,))
        txt = f.lower(jax.ShapeDtypeStruct((64, 4), jnp.float32)).compile().as_text()
        a = analyze_module(txt)
        assert sum(a.collectives.counts.values()) >= 1, a.collectives
        assert a.collectives.total_operand_bytes > 0
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr


def test_roofline_constants_are_v5e():
    assert PEAK_FLOPS == 197e12 and HBM_BW == 819e9 and ICI_BW == 50e9


def test_reports_loadable():
    import os
    from repro.analysis.roofline import load_reports
    path = "reports/roofline_16x16.json"
    if not os.path.exists(path):
        pytest.skip("dry-run report not generated yet")
    reps = load_reports(path)
    cells = {(r["arch_id"], r["shape"]) for r in reps}
    assert len(cells) >= 40
    for r in reps:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["hlo_flops"] > 0
