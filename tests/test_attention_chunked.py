"""Chunked (flash-in-XLA) sdpa equals the unchunked reference (§Perf A5/B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers.attention import sdpa


@pytest.mark.parametrize("win,cap,kvv", [
    (0, 0.0, None),
    (16, 20.0, 48),
    (0, 0.0, 40),
    (7, 0.0, None),
])
def test_chunked_sdpa_matches(win, cap, kvv):
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 12, 6, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 3, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 48, 3, 16))
    pos = jnp.broadcast_to(36 + jnp.arange(12)[None], (2, 12))
    kvv_a = None if kvv is None else jnp.asarray(kvv)
    a = sdpa(q, k, v, causal=True, window=win, softcap=cap, scale=0.25,
             q_positions=pos, kv_valid_len=kvv_a, kv_chunk=0)
    b = sdpa(q, k, v, causal=True, window=win, softcap=cap, scale=0.25,
             q_positions=pos, kv_valid_len=kvv_a, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_chunked_sdpa_mla_dims():
    """MLA-style: q/k dim != v dim."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 4, 24))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 4, 24))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 4, 12))
    pos = jnp.broadcast_to(24 + jnp.arange(8)[None], (1, 8))
    a = sdpa(q, k, v, causal=True, window=0, softcap=0.0, scale=0.2,
             q_positions=pos, kv_chunk=0)
    b = sdpa(q, k, v, causal=True, window=0, softcap=0.0, scale=0.2,
             q_positions=pos, kv_chunk=8)
    assert a.shape == (1, 8, 4, 12)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_grouped_sdpa_matches_flash_ref():
    """The grouped-einsum sdpa (no repeat_kv) equals the flashattn oracle."""
    from repro.kernels import flash_attention_ref
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 24, 32))  # B,H,S,dh
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 2, 24, 32))
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 2, 24, 32))
    want = flash_attention_ref(q, k, v, causal=True)
    pos = jnp.broadcast_to(jnp.arange(24)[None], (2, 24))
    got = sdpa(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
               causal=True, window=0, softcap=0.0, scale=32 ** -0.5,
               q_positions=pos).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
