"""Chaos suite for ``repro.fault``: crash-safe WAL recovery, shard-loss
degradation, and deadline budgets with certified partial results.

Three families of claims, each tested against a deterministic oracle:

- **WAL** — a live index recovered from (checkpoint + WAL tail) is
  bit-identical to an uninterrupted control run over the durable records,
  under torn tails, bit flips, and prune cycles.
- **Shard loss** — the degraded merge equals the healthy merge restricted
  to surviving shards (per-shard searches are deterministic and shards
  partition the corpus), with the loss honestly annotated.
- **Deadlines** — under an injectable fake clock, expired lanes finalize
  into certified partials (every returned id exact-distance-verified in
  radius), results grow monotonically with the deadline, and a lane that
  completes is bitwise-identical to the no-deadline run.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BuildConfig, RangeConfig, RangeSearchEngine, SearchConfig,
    average_precision, build_knn_graph, build_vamana, exact_range_search,
)
from repro.core.corpus import corpus_raw
from repro.core.range_search import RangeResult
from repro.dist.sharded_engine import build_sharded
from repro.fault import (
    DEADLINE_EXPIRED, ERROR_CODES, QUEUE_FULL, SHARD_LOST, FaultInjector,
    RetryPolicy, ShardTimeout, WriteAheadLog, fault_tolerant_sharded_search,
    validate_shard_result,
)
from repro.fault.wal import encode_record
from repro.live import LiveConfig, LiveIndex
from repro.serve import RangeServer, Request, ServerConfig
from repro.train import CheckpointManager
from repro.utils import INVALID_ID

FAST = RetryPolicy(max_attempts=3, backoff_s=0.0)


# ---------------------------------------------------------------------------
# WAL: record framing, torn tails, pruning
# ---------------------------------------------------------------------------

def _wal(tmp_path, name="wal.bin"):
    return WriteAheadLog(str(tmp_path / name))


def test_wal_roundtrip_and_seq_filter(tmp_path):
    wal = _wal(tmp_path)
    vecs = np.arange(12, dtype=np.float32).reshape(3, 4)
    wal.append(1, "insert", dict(ext_ids=np.asarray([7, 8, 9]), vecs=vecs))
    wal.append(2, "delete", dict(ext_ids=np.asarray([8])))
    wal.append(3, "consolidate")
    records, durable, torn = wal.scan()
    assert not torn and durable > 0
    assert [(r.seq, r.op) for r in records] == [
        (1, "insert"), (2, "delete"), (3, "consolidate")]
    np.testing.assert_array_equal(records[0].arrays["vecs"], vecs)
    np.testing.assert_array_equal(records[1].arrays["ext_ids"], [8])
    assert records[2].arrays == {}
    assert wal.last_seq == 3
    # replay filters strictly past the given sequence
    assert [r.seq for r in wal.replay(after_seq=1)] == [2, 3]
    assert [r.seq for r in wal.replay(after_seq=3)] == []


def test_wal_torn_tail_at_every_cut(tmp_path):
    """A record cut at ANY byte boundary ends the replayable prefix; the
    records before it survive untouched and truncate_torn_tail makes the
    log appendable again."""
    wal = _wal(tmp_path)
    wal.append(1, "delete", dict(ext_ids=np.asarray([1])))
    wal.append(2, "delete", dict(ext_ids=np.asarray([2])))
    base = open(wal.path, "rb").read()
    rec3 = encode_record(3, "delete", dict(ext_ids=np.asarray([3])))
    wal.close()
    for cut in (1, 4, 13, len(rec3) // 2, len(rec3) - 1):
        with open(wal.path, "wb") as f:
            f.write(base + rec3[:cut])
        torn = WriteAheadLog(str(tmp_path / "wal.bin"))
        records, durable, is_torn = torn.scan()
        assert is_torn and durable == len(base)
        assert [r.seq for r in records] == [1, 2], f"cut={cut}"
        assert torn.truncate_torn_tail()
        torn.append(3, "delete", dict(ext_ids=np.asarray([3])))
        assert [r.seq for r in torn.replay()] == [1, 2, 3]
        torn.close()


def test_wal_bitflip_invalidates_record_as_unit(tmp_path):
    wal = _wal(tmp_path)
    n1 = wal.append(1, "consolidate")
    wal.append(2, "consolidate")
    wal.append(3, "consolidate")
    raw = bytearray(open(wal.path, "rb").read())
    raw[n1 + 8] ^= 0x40  # flip one bit inside record 2
    with open(wal.path, "wb") as f:
        f.write(raw)
    records, _, torn = wal.scan()
    # the flipped record AND everything after it are discarded: a replay
    # must never skip over a bad record and apply later ones out of order
    assert torn and [r.seq for r in records] == [1]


def test_wal_prune_through_keeps_tail_atomically(tmp_path):
    wal = _wal(tmp_path)
    for s in range(1, 6):
        wal.append(s, "delete", dict(ext_ids=np.asarray([s])))
    assert wal.prune_through(3) == 3
    assert [r.seq for r in wal.replay()] == [4, 5]
    wal.append(6, "consolidate")  # the handle survives the rewrite
    assert wal.last_seq == 6


def test_wal_prune_crash_is_before_or_after_never_torn(tmp_path, monkeypatch):
    """Kill the process at the prune's atomic rename: the log on disk is
    EXACTLY the old log (crash before the rename) or EXACTLY the pruned
    tail (crash after), never a hybrid — and the retried prune succeeds."""
    import os

    real_replace = os.replace
    wal = _wal(tmp_path)
    for s in range(1, 6):
        wal.append(s, "delete", dict(ext_ids=np.asarray([s])))

    def boom_before(src, dst):
        raise OSError("power cut before rename")

    monkeypatch.setattr(os, "replace", boom_before)
    with pytest.raises(OSError, match="power cut"):
        wal.prune_through(3)
    survivor = _wal(tmp_path)  # reopen, as recovery would
    records, _, torn = survivor.scan()
    assert not torn and [r.seq for r in records] == [1, 2, 3, 4, 5]
    survivor.close()

    def boom_after(src, dst):
        real_replace(src, dst)
        raise OSError("power cut after rename")

    monkeypatch.setattr(os, "replace", boom_after)
    retry = _wal(tmp_path)
    with pytest.raises(OSError, match="power cut"):
        retry.prune_through(3)
    survivor = _wal(tmp_path)
    records, _, torn = survivor.scan()
    assert not torn and [r.seq for r in records] == [4, 5]  # prune landed
    survivor.close()

    monkeypatch.setattr(os, "replace", real_replace)
    final = _wal(tmp_path)
    assert final.prune_through(3) == 0  # idempotent retry: nothing left
    final.append(6, "consolidate")  # and the log takes appends again
    assert [r.seq for r in final.replay()] == [4, 5, 6]


# ---------------------------------------------------------------------------
# crash-kill recovery: checkpoint + WAL tail == uninterrupted control
# ---------------------------------------------------------------------------

_D = 8


def _pts(seed, n=96):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((4, _D)).astype(np.float32) * 3
    return (centers[rng.integers(0, 4, n)]
            + rng.standard_normal((n, _D)).astype(np.float32) * 0.3)


def _mk_live(pts):
    return LiveIndex.create(
        pts, LiveConfig(capacity=192, insert_batch=16),
        BuildConfig(max_degree=8, beam=16, insert_batch=32), metric="l2")


def _mutations(seed, n_ops=12):
    """A seeded mixed mutation stream (inserts / deletes / consolidates)."""
    rng = np.random.default_rng(seed + 1000)
    ops = []
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.55:
            k = int(rng.integers(1, 5))
            ops.append(("insert",
                        rng.standard_normal((k, _D)).astype(np.float32)))
        elif roll < 0.9:
            ids = rng.integers(0, 120, size=int(rng.integers(1, 4)))
            ops.append(("delete", ids.astype(np.int64)))
        else:
            ops.append(("consolidate", None))
    return ops


def _apply(idx, op, arg):
    if op == "insert":
        idx.insert(arg)
    elif op == "delete":
        idx.delete(arg)
    else:
        idx.consolidate()


def _state(idx):
    return dict(
        points=np.asarray(corpus_raw(idx.points)),
        neighbors=np.asarray(idx.neighbors),
        start_ids=np.asarray(idx.start_ids),
        ext_ids=np.asarray(idx.ext_ids),
        tombstones=np.asarray(idx.tombstones),
        counters=np.asarray([idx.live_count, idx.next_ext_id, idx.epoch]),
        dead=np.asarray(sorted(idx._dead), np.int64),
    )


def _assert_state_equal(got, want):
    sg, sw = _state(got), _state(want)
    for k in sw:
        np.testing.assert_array_equal(sg[k], sw[k], err_msg=k)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_crash_recovery_bit_identical(tmp_path, seed):
    """Kill-at-any-point recovery: apply a mutation stream with a
    checkpoint mid-stream, crash with a torn record on disk, restore from
    (checkpoint + WAL) — the recovered index is bit-identical to a control
    that ran the stream uninterrupted."""
    pts = _pts(seed)
    ops = _mutations(seed)
    control = _mk_live(pts)
    victim = _mk_live(pts)
    victim.attach_wal(_wal(tmp_path))
    cm = CheckpointManager(str(tmp_path / "ck"))
    cut = len(ops) // 2
    for i, (op, arg) in enumerate(ops):
        _apply(control, op, arg)
        _apply(victim, op, arg)
        if i == cut:
            victim.save(cm)
    seq_durable = victim.wal_seq
    # crash mid-append: a half-written record lands after the durable tail
    with open(str(tmp_path / "wal.bin"), "ab") as f:
        f.write(encode_record(seq_durable + 1, "consolidate", {})[:9])

    recovered = LiveIndex.restore(cm, wal=_wal(tmp_path))
    _assert_state_equal(recovered, control)
    assert recovered.wal_seq == seq_durable
    # the recovered index answers queries identically to the control
    cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16, visit_cap=64),
                      mode="greedy", result_cap=128)
    qs = jnp.asarray(pts[:8] + 0.01)
    ra = control.range(qs, 2.0, cfg=cfg)
    rb = recovered.range(qs, 2.0, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rb.dists))
    # the truncated tail is gone and the log takes new appends: a SECOND
    # crash/recovery cycle starting from here stays consistent
    recovered.insert(np.ones((1, _D), np.float32))
    control.insert(np.ones((1, _D), np.float32))
    again = LiveIndex.restore(cm, wal=_wal(tmp_path))
    _assert_state_equal(again, control)


def test_wal_checkpoint_prune_cycle(tmp_path):
    """After a durable checkpoint the WAL may be pruned through the saved
    wal_seq; recovery then replays only the post-checkpoint tail."""
    pts = _pts(7)
    ops = _mutations(7, n_ops=10)
    control = _mk_live(pts)
    victim = _mk_live(pts)
    wal = _wal(tmp_path)
    victim.attach_wal(wal)
    cm = CheckpointManager(str(tmp_path / "ck"))
    for op, arg in ops[:5]:
        _apply(control, op, arg)
        _apply(victim, op, arg)
    victim.save(cm)
    wal.prune_through(victim.wal_seq)
    for op, arg in ops[5:]:
        _apply(control, op, arg)
        _apply(victim, op, arg)
    recovered = LiveIndex.restore(cm, wal=_wal(tmp_path))
    _assert_state_equal(recovered, control)


def test_failed_insert_is_never_logged(tmp_path):
    """Write-ahead means a logged record MUST be replayable: an insert that
    cannot apply (capacity) validates before logging, so the log never
    carries a record whose replay would raise."""
    pts = _pts(3)
    idx = _mk_live(pts)
    wal = _wal(tmp_path)
    idx.attach_wal(wal)
    with pytest.raises(ValueError, match="capacity"):
        idx.insert(np.zeros((200, _D), np.float32))
    assert wal.last_seq == -1 and idx.wal_seq == 0 and idx.epoch == 0
    with pytest.raises(ValueError, match="already present"):
        idx.insert(np.zeros((1, _D), np.float32),
                   ext_ids=np.asarray([0], np.int64))
    assert wal.last_seq == -1  # duplicate-id rejection logs nothing either


def test_checkpoint_save_is_idempotent_and_durable(tmp_path):
    """CheckpointManager.save fsyncs payloads + directories around the
    atomic rename; a completed step re-saves as a no-op and never leaves a
    .tmp dir behind."""
    import os
    cm = CheckpointManager(str(tmp_path), keep=2)
    p = cm.save(1, {"a": np.arange(4)})
    assert cm.save(1, {"a": np.zeros(4)}) == p  # already durable: no-op
    state, step = cm.restore({"a": np.zeros(4)})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(state["a"]), np.arange(4))
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


# ---------------------------------------------------------------------------
# fault injection: determinism and precedence
# ---------------------------------------------------------------------------

def test_injector_deterministic_and_precedence():
    a = FaultInjector(seed=3, p_timeout=0.3, p_error=0.2, p_garbage=0.2)
    b = FaultInjector(seed=3, p_timeout=0.3, p_error=0.2, p_garbage=0.2)
    # counter-based draws: identical per (shard, attempt), any call order
    got = [(s, t, a.fault_for(s, t)) for s in range(4) for t in range(3)]
    want = [(s, t, b.fault_for(s, t))
            for s in reversed(range(4)) for t in reversed(range(3))]
    assert sorted(got) == sorted(want)
    assert any(k is not None for _, _, k in got)  # faults actually fire

    down = FaultInjector(down_shards=(2,))
    assert all(down.fault_for(2, t) == "timeout" for t in range(5))
    assert down.fault_for(0, 0) is None
    with pytest.raises(ShardTimeout):
        down.raise_if_faulted(2, 0)

    # script pins exact outcomes over both down_shards and probability
    scripted = FaultInjector(down_shards=(1,),
                             script={(1, 0): None, (0, 0): "error"})
    assert scripted.fault_for(1, 0) is None
    assert scripted.fault_for(1, 1) == "timeout"
    assert scripted.fault_for(0, 0) == "error"
    assert scripted.injected.get("error") == 1

    with pytest.raises(ValueError, match="probabilities"):
        FaultInjector(p_timeout=0.7, p_error=0.7)
    with pytest.raises(ValueError, match="script"):
        FaultInjector(script={(0, 0): "explode"})


# ---------------------------------------------------------------------------
# shard-loss degradation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sharded_setup():
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((8, 8)).astype(np.float32) * 3
    pts = (centers[rng.integers(0, 8, 800)]
           + rng.standard_normal((800, 8)).astype(np.float32) * 0.3)
    centers_j = jnp.asarray(centers)

    def _builder(p):
        # a kNN graph over well-separated clusters is disconnected: give
        # each shard one entry point per cluster so every component is
        # reachable (a lone medoid start would strand 7 of 8 clusters)
        lab = np.asarray(jnp.argmin(
            jnp.sum((p[:, None] - centers_j[None]) ** 2, -1), axis=1))
        starts = np.asarray([np.flatnonzero(lab == c)[0] for c in range(8)],
                            np.int32)
        return build_knn_graph(p, k=10), jnp.asarray(starts)

    corpus = build_sharded(pts, 4, _builder)
    qs = jnp.asarray(pts[:24] + 0.01)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          expand_width=4),
                      mode="greedy", result_cap=512)
    return pts, corpus, qs, cfg


def _lane_rows(res):
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    valid = ids != INVALID_ID
    return ids, dists, valid


def test_shard_loss_equals_healthy_restricted_to_survivors(sharded_setup):
    pts, corpus, qs, cfg = sharded_setup
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    assert healthy.complete and healthy.coverage == 1.0
    assert healthy.code is None and list(healthy.attempts) == [1, 1, 1, 1]

    lost = fault_tolerant_sharded_search(
        corpus=corpus, queries=qs, r=2.0, cfg=cfg,
        injector=FaultInjector(down_shards=(1,)), retry=FAST)
    assert not lost.complete and lost.code == SHARD_LOST
    assert lost.shards_ok == 3 and lost.shards_total == 4
    assert lost.coverage == 0.75
    assert lost.faults[1] == "timeout"
    assert list(lost.attempts) == [1, FAST.max_attempts, 1, 1]

    # surviving-shard results are EXACTLY the healthy merge minus the lost
    # shard's rows: degradation truncates coverage, never perturbs results
    off = np.asarray(corpus.offsets)
    lo, hi = int(off[1]), min(int(off[1]) + corpus.shard_size, corpus.n_total)
    h_ids, h_dists, h_valid = _lane_rows(healthy.result)
    l_ids, l_dists, l_valid = _lane_rows(lost.result)
    assert not np.asarray(healthy.result.overflow).any()  # cap not binding
    for q in range(h_ids.shape[0]):
        keep = h_valid[q] & ((h_ids[q] < lo) | (h_ids[q] >= hi))
        np.testing.assert_array_equal(l_ids[q][l_valid[q]], h_ids[q][keep])
        np.testing.assert_array_equal(l_dists[q][l_valid[q]], h_dists[q][keep])
    np.testing.assert_array_equal(
        np.asarray(lost.result.count),
        np.asarray(healthy.result.count)
        - np.sum(h_valid & (h_ids >= lo) & (h_ids < hi), axis=1))

    # and the degraded answer still scores against the brute-force oracle
    # restricted to surviving rows (the best any search over them can do)
    mask = np.ones(len(pts), bool)
    mask[lo:hi] = False
    sub_ids = np.nonzero(mask)[0]
    gt = exact_range_search(jnp.asarray(pts[mask]), qs, 2.0)
    lut = np.full(len(pts), INVALID_ID, np.int64)
    lut[sub_ids] = np.arange(len(sub_ids))
    rows = np.where(l_ids != INVALID_ID, lut[np.minimum(l_ids, len(pts) - 1)],
                    np.int64(INVALID_ID))
    ap = average_precision(np.asarray(gt[0]), np.asarray(gt[2]), rows,
                           np.asarray(lost.result.count))
    assert ap > 0.9, ap


def test_transient_faults_retry_to_identical(sharded_setup):
    """garbage then timeout then a clean answer on one shard: retries (with
    recorded backoff) recover the exact healthy result."""
    _, corpus, qs, cfg = sharded_setup
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    sleeps = []
    flaky = fault_tolerant_sharded_search(
        corpus=corpus, queries=qs, r=2.0, cfg=cfg,
        injector=FaultInjector(script={(2, 0): "garbage", (2, 1): "timeout"}),
        retry=RetryPolicy(max_attempts=3, backoff_s=0.1, backoff_factor=2.0),
        sleep=sleeps.append)
    assert flaky.complete and flaky.coverage == 1.0 and flaky.code is None
    assert list(flaky.attempts) == [1, 1, 3, 1]
    assert flaky.faults[2] == "timeout"  # the LAST observed fault
    assert sleeps == [0.1, 0.2]  # exponential backoff between attempts
    np.testing.assert_array_equal(np.asarray(flaky.result.ids),
                                  np.asarray(healthy.result.ids))
    np.testing.assert_array_equal(np.asarray(flaky.result.dists),
                                  np.asarray(healthy.result.dists))


def test_all_shards_lost_yields_empty_wellformed_result(sharded_setup):
    _, corpus, qs, cfg = sharded_setup
    dead = fault_tolerant_sharded_search(
        corpus=corpus, queries=qs, r=2.0, cfg=cfg,
        injector=FaultInjector(down_shards=(0, 1, 2, 3)),
        retry=RetryPolicy(max_attempts=2, backoff_s=0.0))
    assert dead.shards_ok == 0 and dead.coverage == 0.0
    assert dead.code == SHARD_LOST
    ids = np.asarray(dead.result.ids)
    assert ids.shape == (qs.shape[0], cfg.result_cap)
    assert np.all(ids == INVALID_ID)
    assert np.all(np.asarray(dead.result.count) == 0)


def _mk_result(ids, dists, cap_count=None):
    ids = jnp.asarray(np.asarray(ids, np.int32))
    n, w = ids.shape
    return RangeResult(
        ids=ids, dists=jnp.asarray(np.asarray(dists, np.float32)),
        count=jnp.asarray(np.asarray(
            cap_count if cap_count is not None
            else (np.asarray(ids) != INVALID_ID).sum(1), np.int32)),
        overflow=jnp.zeros(n, bool), n_visited=jnp.zeros(n, jnp.int32),
        n_dist=jnp.zeros(n, jnp.int32), es_stopped=jnp.zeros(n, bool),
        phase2=jnp.zeros(n, bool), n_rerank=jnp.zeros(n, jnp.int32))


def test_validate_shard_result_invariants():
    radii = np.asarray([1.0], np.float32)
    ok = _mk_result([[12, INVALID_ID]], [[0.5, np.inf]])
    assert validate_shard_result(ok, 10, 10, 100, radii)
    # id outside the shard's global row range
    assert not validate_shard_result(
        _mk_result([[9, INVALID_ID]], [[0.5, np.inf]]), 10, 10, 100, radii)
    # id past the true corpus size (pad row leaked)
    assert not validate_shard_result(
        _mk_result([[15, INVALID_ID]], [[0.5, np.inf]]), 10, 10, 12, radii)
    # negative / non-finite / out-of-radius distances
    assert not validate_shard_result(
        _mk_result([[12, INVALID_ID]], [[-0.5, np.inf]]), 10, 10, 100, radii)
    assert not validate_shard_result(
        _mk_result([[12, INVALID_ID]], [[np.nan, np.inf]]), 10, 10, 100, radii)
    assert not validate_shard_result(
        _mk_result([[12, INVALID_ID]], [[1.5, np.inf]]), 10, 10, 100, radii)
    # count exceeding the result buffer
    assert not validate_shard_result(
        _mk_result([[12, INVALID_ID]], [[0.5, np.inf]], cap_count=[3]),
        10, 10, 100, radii)


def test_retry_policy_backoff_cap_and_jitter():
    rp = RetryPolicy(backoff_s=1.0, backoff_factor=10.0, backoff_max_s=5.0)
    assert rp.delay_s(0) == 1.0
    assert rp.delay_s(1) == 5.0  # 10.0 capped at backoff_max_s
    assert rp.delay_s(3) == 5.0
    # default jitter=0.0: delays are exact (the pinned-backoff tests rely
    # on this)
    assert RetryPolicy(backoff_s=0.05).delay_s(1) == 0.1

    j = RetryPolicy(backoff_s=1.0, backoff_factor=1.0, jitter=0.5, seed=7)
    d = [j.delay_s(0, key=s) for s in range(8)]
    assert all(1.0 <= x <= 1.5 for x in d)  # stretch in [1, 1 + jitter]
    assert len(set(d)) > 1  # per-shard keys de-synchronize retries...
    j2 = RetryPolicy(backoff_s=1.0, backoff_factor=1.0, jitter=0.5, seed=7)
    assert d == [j2.delay_s(0, key=s) for s in range(8)]  # ...deterministically


def test_validate_shard_result_relative_tolerance():
    """An honest large-radius answer can exceed r by float error that
    scales with r: atol alone mislabels it garbage, atol + rtol*r passes
    it, and a grossly-out answer still fails."""
    radii = np.asarray([100.0], np.float32)
    near = _mk_result([[12, INVALID_ID]], [[100.0005, np.inf]])
    assert not validate_shard_result(near, 10, 10, 100, radii,
                                     atol=1e-4, rtol=0.0)
    assert validate_shard_result(near, 10, 10, 100, radii,
                                 atol=1e-4, rtol=1e-5)
    far = _mk_result([[12, INVALID_ID]], [[101.0, np.inf]])
    assert not validate_shard_result(far, 10, 10, 100, radii,
                                     atol=1e-4, rtol=1e-5)


def test_garbage_injection_is_caught_not_merged(sharded_setup):
    """A shard answering garbage on EVERY attempt must be dropped by
    validation — the merge never contains an unvalidated id."""
    _, corpus, qs, cfg = sharded_setup
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    sick = fault_tolerant_sharded_search(
        corpus=corpus, queries=qs, r=2.0, cfg=cfg,
        injector=FaultInjector(script={(3, t): "garbage" for t in range(3)}),
        retry=FAST)
    assert not sick.complete and sick.shards_ok == 3
    assert sick.faults[3] == "garbage"
    ids, dists, valid = _lane_rows(sick.result)
    off = np.asarray(corpus.offsets)
    lo = int(off[3])
    assert np.all(~valid | (ids < lo))  # nothing from the sick shard
    assert np.all(dists[valid] <= 2.0 + 1e-4)  # all merged ids in radius


def test_server_sharded_degraded_annotations(sharded_setup):
    """The serving path surfaces degradation: responses annotated with
    shards_ok/shards_total/coverage/code, stats count retries and losses,
    and results stay certified (exact in-radius distances)."""
    pts, corpus, qs, cfg = sharded_setup
    with pytest.raises(ValueError, match="sharded"):
        RangeServer(None, cfg, injector=FaultInjector())
    srv = RangeServer(None, cfg, ServerConfig(max_batch=8), sharded=corpus,
                      injector=FaultInjector(down_shards=(3,)),
                      retry=RetryPolicy(max_attempts=2, backoff_s=0.0))
    qs_np = np.asarray(qs)
    for i in range(8):
        srv.submit(Request(req_id=i, query=qs_np[i], radius=2.0))
    resp = srv.run_until_drained()
    assert len(resp) == 8
    d2 = np.sum((np.asarray(pts)[None] - qs_np[:8, None]) ** 2, axis=-1)
    for r in resp:
        assert not r.complete and r.code == SHARD_LOST
        assert r.shards_ok == 3 and r.shards_total == 4
        assert r.coverage == 0.75
        np.testing.assert_allclose(d2[r.req_id, r.ids], r.dists, atol=1e-4)
    assert srv.stats["degraded_batches"] >= 1
    assert srv.stats["shards_lost"] >= 1
    assert srv.stats["shard_retries"] >= 1

    # healthy host fan-out (no mesh, no injector): complete annotations
    ok = RangeServer(None, cfg, ServerConfig(max_batch=8), sharded=corpus)
    ok.submit(Request(req_id=0, query=qs_np[0], radius=2.0))
    (r0,) = ok.run_until_drained()
    assert r0.complete and r0.coverage == 1.0 and r0.code is None
    assert r0.shards_ok == 4 and r0.shards_total == 4


# ---------------------------------------------------------------------------
# deadlines: queued shed, certified partials, monotonicity
# ---------------------------------------------------------------------------

class FakeClock:
    """Injectable monotonic time: frozen until advanced by the test."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def clustered():
    """Clustered corpus where greedy range search recovers exact in-range
    sets — certification and bitwise-equality claims are non-flaky here."""
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((8, 12)).astype(np.float32) * 3
    pts = jnp.asarray(centers[rng.integers(0, 8, 1200)] +
                      rng.standard_normal((1200, 12)).astype(np.float32) * 0.4)
    g = build_vamana(pts, BuildConfig(max_degree=24, beam=48, insert_batch=256,
                                      two_pass=True))
    return pts, g


_DL_CFG = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=256),
                      mode="greedy", result_cap=512)


def _drive_with_deadline(eng, cfg, qs, radii, deadline_s, step_dt=1.0):
    """Submit everything at t=0, then step with the fake clock advancing
    ``step_dt`` per step until drained. Returns ({req_id: Response}, srv)."""
    clock = FakeClock()
    srv = RangeServer(eng, cfg,
                      ServerConfig(max_batch=32, continuous=True, lanes=16,
                                   slice_rounds=1),
                      clock=clock)
    for i in range(len(qs)):
        srv.submit(Request(req_id=i, query=qs[i], radius=float(radii[i]),
                           deadline_s=deadline_s))
    resp, guard = [], 0
    while srv.pending() or srv.in_flight():
        resp.extend(srv.step())
        clock.advance(step_dt)
        guard += 1
        assert guard < 3000, "pool stalled under deadline expiry"
    assert sorted(r.req_id for r in resp) == list(range(len(qs)))
    return {r.req_id: r for r in resp}, srv


def _assert_certified(resp, pts, qs, radii, exact_dists=True):
    """Every returned id — partial or not — is certified in-radius by the
    exact distance: partials are truncated, never corrupted. With an f32
    corpus the reported distances are the exact squared distances too;
    int8 reports guard-band-reranked estimates, so only set membership is
    exact there (distance equality is checked against the baseline run
    instead, in the caller)."""
    d2 = np.sum((np.asarray(pts)[None] - np.asarray(qs)[:, None]) ** 2,
                axis=-1)
    for i, r in resp.items():
        if r.op != "range":
            continue
        assert np.all(d2[i, r.ids] <= radii[i] + 1e-3), i
        if exact_dists:
            assert np.all(r.dists <= radii[i] + 1e-5), i
            np.testing.assert_allclose(d2[i, r.ids], r.dists, atol=1e-4)
        assert len(np.unique(r.ids)) == len(r.ids)


def test_deadline_zero_and_queued_shed(clustered):
    pts, g = clustered
    eng = RangeSearchEngine.from_graph(pts, g)
    clock = FakeClock()
    srv = RangeServer(eng, _DL_CFG, ServerConfig(max_batch=8), clock=clock)
    q = np.asarray(pts[:4]) + 0.01
    with pytest.raises(ValueError, match="deadline_s"):
        srv.submit(Request(req_id=9, query=q[0], radius=0.5, deadline_s=-1.0))
    # frozen clock: a ZERO deadline still gets the work done (expiry is
    # strictly later-than, so t == deadline_at serves normally)
    srv.submit(Request(req_id=0, query=q[0], radius=0.5, deadline_s=0.0))
    (r0,) = srv.step()
    assert r0.op == "range" and r0.complete and r0.code is None

    # queued past the budget: shed with a structured error, never searched
    srv.submit(Request(req_id=1, query=q[1], radius=0.5, deadline_s=0.5))
    srv.submit(Request(req_id=2, query=q[2], radius=0.5, deadline_s=5.0))
    srv.submit(Request(req_id=3, query=q[3], radius=0.5))
    clock.advance(1.0)
    out = {r.req_id: r for r in srv.step()}
    assert out[1].op == "error" and out[1].code == DEADLINE_EXPIRED
    assert not out[1].complete and out[1].coverage == 0.0
    assert len(out[1].ids) == 0
    assert out[2].op == "range" and out[2].complete
    assert out[3].op == "range" and out[3].complete  # None: never expires
    assert srv.stats["deadline_shed"] == 1


@pytest.mark.parametrize("corpus_dtype", ["float32", "int8"])
def test_deadline_monotone_and_certified(clustered, corpus_dtype):
    """The deadline metamorphic suite, f32 and quantized corpora:

    - a longer deadline never returns fewer results (per request, the id
      set grows monotonically — the greedy buffer is append-only and the
      exact-rerank filter preserves subset relations);
    - responses marked complete are bitwise-identical to the no-deadline
      run (lanes are independent; expiry of others never perturbs them);
    - every partial is certified (exact in-radius distances only) and
      annotated (complete=False, coverage in [0, 1), code set)."""
    pts, g = clustered
    eng = RangeSearchEngine.from_graph(pts, g, corpus_dtype=corpus_dtype)
    cfg = dataclasses.replace(
        _DL_CFG, search=dataclasses.replace(_DL_CFG.search,
                                            corpus_dtype=corpus_dtype))
    qs = np.asarray(pts[:16]) + 0.01
    radii = np.where(np.arange(16) % 2 == 0, 9.0, 0.5).astype(np.float32)
    deadlines = [0.5, 2.5, 6.5] if corpus_dtype == "float32" else [2.5]

    exact = corpus_dtype == "float32"
    base, _ = _drive_with_deadline(eng, cfg, qs, radii, None)
    assert all(r.complete and r.coverage == 1.0 and r.code is None
               for r in base.values())
    _assert_certified(base, pts, qs, radii, exact_dists=exact)

    runs = []
    for d in deadlines:
        resp, srv = _drive_with_deadline(eng, cfg, qs, radii, d)
        _assert_certified(resp, pts, qs, radii, exact_dists=exact)
        for i, r in resp.items():
            if r.complete:
                # certified complete == bitwise-equal to the unbounded run
                np.testing.assert_array_equal(r.ids, base[i].ids)
                np.testing.assert_array_equal(r.dists, base[i].dists)
                assert r.count == base[i].count
            else:
                assert r.code == DEADLINE_EXPIRED
                assert 0.0 <= r.coverage < 1.0
                assert set(r.ids.tolist()) <= set(base[i].ids.tolist())
                # truncated, never corrupted: each surviving id carries
                # the same (deterministic) distance the full run reports
                lut = dict(zip(base[i].ids.tolist(), base[i].dists.tolist()))
                for j, d_j in zip(r.ids.tolist(), r.dists.tolist()):
                    assert d_j == lut[j], (i, j)
        runs.append(resp)
    if corpus_dtype == "float32":
        # the tightest deadline really truncated something (heavy lanes at
        # radius 9 need many slice_rounds=1 ticks; 0.5s expires them), and
        # monotonicity holds pairwise across the deadline ladder
        assert any(not r.complete for r in runs[0].values())
        for lo, hi in zip(runs, runs[1:]):
            for i in range(16):
                assert set(lo[i].ids.tolist()) <= set(hi[i].ids.tolist()), i
                assert lo[i].count <= hi[i].count


def test_deadline_partials_free_the_pool(clustered):
    """Expired lanes retire as partials BEFORE the tick, so one saturated
    straggler can never stall the pool: point traffic behind it keeps
    flowing and finishes complete."""
    pts, g = clustered
    eng = RangeSearchEngine.from_graph(pts, g)
    qs = np.asarray(pts[:12]) + 0.01
    radii = np.full(12, 0.5, np.float32)
    radii[0] = 9.0  # one heavy straggler
    resp, srv = _drive_with_deadline(eng, _DL_CFG, qs, radii, 1.5)
    assert not resp[0].complete and resp[0].code == DEADLINE_EXPIRED
    assert srv.stats["deadline_partial"] >= 1
    for i in range(1, 12):
        assert resp[i].complete, i
    _assert_certified(resp, pts, qs, radii)


def test_error_code_taxonomy_and_queue_full(clustered):
    assert {QUEUE_FULL, DEADLINE_EXPIRED, SHARD_LOST} <= set(ERROR_CODES)
    pts, g = clustered
    eng = RangeSearchEngine.from_graph(pts, g)
    srv = RangeServer(eng, _DL_CFG, ServerConfig(max_batch=4, max_queue=2))
    q = np.asarray(pts[0])
    assert srv.submit(Request(req_id=0, query=q, radius=0.5)) is None
    assert srv.submit(Request(req_id=1, query=q, radius=0.5)) is None
    rej = srv.submit(Request(req_id=2, query=q, radius=0.5))
    assert rej is not None and rej.op == "error" and rej.code == QUEUE_FULL
    assert rej.code in ERROR_CODES and not rej.complete
    assert srv.stats["rejected"] == 1
