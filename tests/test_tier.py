"""Tiered-corpus suite: bitwise parity, cache adversaries, crash safety.

The backbone claim is the `repro.tier` parity contract: an engine whose raw
f32 rerank rows live in a host-RAM row store answers BIT-IDENTICALLY to the
fully-resident engine sharing the same codes/graph — under any cache size
(including 0), any eviction history, any query order, across fused /
compacted / sharded execution and live churn. Everything else here guards
the machinery around that contract: the fetch planner's dedup/bucketing,
the LRU cache's reference semantics, the `REPRO_TIER_CACHE_ROWS` memcap
hook, `TierFetchError` degrading like shard loss instead of crashing, and
the checkpoint path keeping the host store and the manifest in agreement
across crashes (torn checkpoints are invisible; restores are mmap-backed).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BuildConfig, RangeConfig, RangeSearchEngine, SearchConfig,
    build_knn_graph, build_vamana,
)
from repro.dist.sharded_engine import build_sharded
from repro.fault import (
    SHARD_LOST, RetryPolicy, fault_tolerant_sharded_search,
)
from repro.kernels.rerank_fetch import fetch_rerank_dists
from repro.live import LiveConfig, LiveIndex
from repro.serve import RangeServer, Request, ServerConfig
from repro.tier import (
    DeviceRowCache, TierFetchError, plan_fetch, tiered_corpus,
)
from repro.train import CheckpointManager

D = 10
BCFG = BuildConfig(max_degree=24, beam=48, insert_batch=256, two_pass=True)
CFG = RangeConfig(search=SearchConfig(beam=48, max_beam=48, visit_cap=192,
                                      expand_width=4),
                  mode="greedy", result_cap=512)
FAST = RetryPolicy(max_attempts=3, backoff_s=0.0)


def _clustered(n, seed=0, d=D, scale=0.35, k=6):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)).astype(np.float32) * 3
    return (centers[rng.integers(0, k, n)]
            + rng.standard_normal((n, d)).astype(np.float32) * scale)


_BASE: dict = {}


def _base():
    """(points (500, D), prebuilt graph, queries (24, D), radius), built
    once; the radius targets ~20 matches/query so the int8 guard band is
    reliably non-empty (the fetch path actually runs)."""
    if not _BASE:
        pts = _clustered(500)
        qs = _clustered(24, seed=3)
        dmat = np.linalg.norm(pts[None] - qs[:, None], axis=-1) ** 2
        _BASE["pts"] = pts
        _BASE["graph"] = build_vamana(jnp.asarray(pts), BCFG)
        _BASE["qs"] = jnp.asarray(qs)
        _BASE["r"] = float(np.quantile(dmat, 20.0 / pts.shape[0]))
    return _BASE["pts"], _BASE["graph"], _BASE["qs"], _BASE["r"]


def _engines(corpus_dtype="int8", cache_rows=24):
    """(resident engine, tiered engine) sharing codes, graph and entries —
    the only difference is where the raw rerank rows live."""
    pts, graph, _, _ = _base()
    eng = RangeSearchEngine.from_graph(jnp.asarray(pts), graph,
                                       corpus_dtype=corpus_dtype)
    src = eng.points if corpus_dtype == "int8" else jnp.asarray(pts)
    tier = tiered_corpus(src, corpus_dtype=corpus_dtype,
                         cache_rows=cache_rows)
    return eng, dataclasses.replace(eng, points=tier)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))


# ---------------------------------------------------------------------------
# bitwise parity: resident vs tiered
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compacted", [True, False], ids=["compacted", "fused"])
@pytest.mark.parametrize("corpus_dtype", ["float32", "int8"])
def test_tiered_bitwise_parity(corpus_dtype, compacted):
    eng, eng_t = _engines(corpus_dtype)
    _, _, qs, r = _base()
    res = eng.range(qs, r, cfg=CFG, compacted=compacted)
    res_t = eng_t.range(qs, r, cfg=CFG, compacted=compacted)
    _assert_bitwise(res, res_t)
    # the acceptance pin: the device row cache stays a small fraction of
    # the raw-row bytes it displaced — the tier may not re-resident them
    b = eng_t.points.budget()
    assert b.device["row_cache"] <= 0.25 * b.host["row_store"], b.as_dict()
    if corpus_dtype == "int8":
        # parity was proven WITH the fetch path engaged, not vacuously
        assert eng_t.points.counters.pairs > 0
        assert int(np.asarray(res_t.n_rerank).sum()) > 0
    else:
        # degenerate float tier: the hot arm IS the raw data — no fetches
        assert eng_t.points.counters.pairs == 0
    # budget surfaces through engine stats
    st = eng_t.stats()
    assert st["memory_budget"]["device_total"] == b.device_total
    assert st["tier"]["pairs"] == eng_t.points.counters.pairs


def test_tiered_parity_per_query_radii():
    eng, eng_t = _engines("int8")
    _, _, qs, r = _base()
    radii = jnp.asarray(np.geomspace(0.25 * r, 2.0 * r, qs.shape[0]),
                        jnp.float32)
    _assert_bitwise(eng.range(qs, radii, cfg=CFG),
                    eng_t.range(qs, radii, cfg=CFG))


def test_cache_eviction_adversarial_ordering():
    """Query order / cache size / eviction history can never change a bit:
    a 4-row cache (thrashing), a disabled cache (pure streaming) and the
    resident engine agree on every permutation of the batch."""
    eng, eng_tiny = _engines("int8", cache_rows=4)
    _, eng_none = _engines("int8", cache_rows=0)
    _, _, qs, r = _base()
    rng = np.random.default_rng(5)
    orders = [np.arange(qs.shape[0]), np.arange(qs.shape[0])[::-1],
              rng.permutation(qs.shape[0]), rng.permutation(qs.shape[0])]
    for order in orders:
        ref = eng.range(qs[order], r, cfg=CFG)
        _assert_bitwise(ref, eng_tiny.range(qs[order], r, cfg=CFG))
        _assert_bitwise(ref, eng_none.range(qs[order], r, cfg=CFG))
    ct, cn = eng_tiny.points.counters, eng_none.points.counters
    assert ct.cache_evictions > 0          # the tiny cache really thrashed
    assert cn.cache_hits == 0              # capacity 0 never caches
    assert cn.fetched_rows == cn.unique_rows
    assert ct.pairs >= ct.unique_rows      # dedup never inflates


def test_device_row_cache_reference_semantics():
    """Unit adversary for the LRU cache: random lookup/insert/invalidate
    interleavings must always (a) return the exact stored row for every
    reported hit, (b) bound the population by capacity, and (c) treat
    invalidated slots as misses."""
    rng = np.random.default_rng(0)
    raw = rng.standard_normal((64, 4)).astype(np.float32)
    cache = DeviceRowCache(4, 8)
    for step in range(120):
        slots = np.unique(rng.integers(0, 64, rng.integers(1, 6)))
        hit, lines = cache.lookup(slots)
        for s, h, ln in zip(slots.tolist(), hit.tolist(), lines.tolist()):
            if h:
                got = np.asarray(cache.rows(np.asarray([ln])))[0]
                np.testing.assert_array_equal(got, raw[s])
        miss = slots[~hit]
        if miss.size:
            cache.insert(miss, jnp.asarray(raw[miss]))
            hit2, _ = cache.lookup(miss)
            assert hit2.all()  # just-inserted rows are immediately hits
        assert len(cache) <= 8
        if step % 7 == 0:
            stale = np.unique(rng.integers(0, 64, 3))
            cache.invalidate(stale)
            hit3, _ = cache.lookup(stale)
            assert not hit3.any()


def test_plan_fetch_dedup_sort_and_buckets():
    slots = np.asarray([7, 3, 7, 7, 1, 9, 3])
    plan = plan_fetch(slots, None, bucket_rows=2)
    assert plan.uniques.tolist() == [1, 3, 7, 9]
    np.testing.assert_array_equal(plan.uniques[plan.inverse], slots)
    assert plan.n_pairs == 7 and plan.n_unique == 4 and plan.n_miss == 4
    assert all(c.size <= 2 for c in plan.miss_chunks)
    cat = np.concatenate(plan.miss_chunks)
    assert (np.diff(cat) > 0).all()  # row-store order: sorted, no dups
    assert plan_fetch(np.asarray([], np.int64)) is None


def test_cache_rows_env_override(monkeypatch):
    pts = jnp.asarray(_clustered(64, seed=2))
    monkeypatch.setenv("REPRO_TIER_CACHE_ROWS", "3")
    assert tiered_corpus(pts).cache.capacity == 3
    # explicit knobs win over the CI memcap env
    assert tiered_corpus(pts, cache_rows=9).cache.capacity == 9
    assert tiered_corpus(pts, resident_mb=1.0).cache.capacity == \
        (1 << 20) // (D * 4)
    monkeypatch.delenv("REPRO_TIER_CACHE_ROWS")
    assert tiered_corpus(pts).cache.capacity == 64 // 8


# ---------------------------------------------------------------------------
# sharded: parity + TierFetchError degradation
# ---------------------------------------------------------------------------

_SHARD: dict = {}


def _shard_base():
    """800 points over 8 clusters, 4 shards, kNN graphs with one entry per
    cluster (the test_fault recipe — disconnected components need them)."""
    if not _SHARD:
        rng = np.random.default_rng(0)
        centers = rng.standard_normal((8, 8)).astype(np.float32) * 3
        pts = (centers[rng.integers(0, 8, 800)]
               + rng.standard_normal((800, 8)).astype(np.float32) * 0.3)
        centers_j = jnp.asarray(centers)

        def _builder(p):
            lab = np.asarray(jnp.argmin(
                jnp.sum((p[:, None] - centers_j[None]) ** 2, -1), axis=1))
            starts = np.asarray(
                [np.flatnonzero(lab == c)[0] for c in range(8)], np.int32)
            return build_knn_graph(p, k=10), jnp.asarray(starts)

        _SHARD["pts"] = pts
        _SHARD["builder"] = _builder
        _SHARD["qs"] = jnp.asarray(pts[:16] + 0.01)
        _SHARD["cfg"] = RangeConfig(
            search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                expand_width=4),
            mode="greedy", result_cap=512)
    return _SHARD["pts"], _SHARD["builder"], _SHARD["qs"], _SHARD["cfg"]


def test_sharded_tiered_bitwise_parity():
    pts, builder, qs, cfg = _shard_base()
    res = build_sharded(pts, 4, builder, corpus_dtype="int8")
    tier = build_sharded(pts, 4, builder, corpus_dtype="int8", tier=True)
    healthy = fault_tolerant_sharded_search(corpus=res, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    tiered = fault_tolerant_sharded_search(corpus=tier, queries=qs, r=2.0,
                                           cfg=cfg, retry=FAST)
    assert healthy.coverage == 1.0 and tiered.coverage == 1.0
    _assert_bitwise(healthy.result, tiered.result)
    assert sum(t.counters.pairs for t in tier.tiers) > 0
    # per-shard caches each respect the resident pin
    for t in tier.tiers:
        b = t.budget()
        assert b.device["row_cache"] <= 0.25 * b.host["row_store"]


def test_tier_fetch_error_degrades_like_shard_loss():
    """A failing host store degrades exactly like a lost shard — annotated
    coverage, no crash — and recovers to the healthy bits once it heals."""
    pts, builder, qs, cfg = _shard_base()
    # resident_mb=0: no cache, so EVERY guard-band row hits the store and
    # the chaos hook cannot be dodged by warm cache lines
    tier = build_sharded(pts, 4, builder, corpus_dtype="int8", tier=True,
                         resident_mb=0.0)
    healthy = fault_tolerant_sharded_search(corpus=tier, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    assert healthy.coverage == 1.0
    assert tier.tiers[1].counters.fetched_rows > 0  # shard 1 really fetches
    tier.tiers[1].store.fail_next = 10_000
    lost = fault_tolerant_sharded_search(corpus=tier, queries=qs, r=2.0,
                                         cfg=cfg, retry=FAST)
    assert not lost.complete and lost.code == SHARD_LOST
    assert lost.shards_ok == 3 and lost.coverage == 0.75
    assert lost.faults[1] == "tier_fetch"
    tier.tiers[1].store.fail_next = 0
    healed = fault_tolerant_sharded_search(corpus=tier, queries=qs, r=2.0,
                                           cfg=cfg, retry=FAST)
    assert healed.coverage == 1.0
    _assert_bitwise(healthy.result, healed.result)


def test_tier_fetch_error_surfaces_unwrapped():
    eng, eng_t = _engines("int8", cache_rows=0)
    _, _, qs, r = _base()
    eng_t.points.store.fail_next = 1
    with pytest.raises(TierFetchError):
        eng_t.range(qs, r, cfg=CFG)
    _assert_bitwise(eng.range(qs, r, cfg=CFG),
                    eng_t.range(qs, r, cfg=CFG))  # healed: bits intact


# ---------------------------------------------------------------------------
# live churn parity + checkpoint crash consistency
# ---------------------------------------------------------------------------

def _live_pair(corpus_dtype):
    pts, graph, _, _ = _base()
    lcfg = LiveConfig(capacity=768, insert_batch=64, consolidate_at=0.25)
    mk = lambda tier: LiveIndex.create(pts, lcfg, BCFG, graph=graph,
                                       corpus_dtype=corpus_dtype, tier=tier)
    return mk(False), mk(True)


@pytest.mark.parametrize("corpus_dtype", ["float32", "int8"])
def test_live_churn_bitwise_parity(corpus_dtype):
    a, b = _live_pair(corpus_dtype)
    _, _, qs, r = _base()
    stream = _clustered(120, seed=7)
    ia, ib = a.insert(stream[:60]), b.insert(stream[:60])
    np.testing.assert_array_equal(ia, ib)
    for live, ids in ((a, ia), (b, ib)):
        live.delete(ids[:20])
        live.delete(np.arange(5, 45))  # initial-row ext ids
    _assert_bitwise(a.range(qs, r, cfg=CFG), b.range(qs, r, cfg=CFG))
    # consolidation rebuilds the tier (fresh store + cache, same counters)
    sa, sb = a.consolidate(), b.consolidate()
    assert sa["n_live"] == sb["n_live"]
    _assert_bitwise(a.range(qs, r, cfg=CFG), b.range(qs, r, cfg=CFG))
    # post-consolidation inserts write through the NEW store
    np.testing.assert_array_equal(a.insert(stream[60:]), b.insert(stream[60:]))
    _assert_bitwise(a.range(qs, r, cfg=CFG), b.range(qs, r, cfg=CFG))
    if corpus_dtype == "int8":
        assert b.points.counters.pairs > 0


def test_live_insert_invalidates_stale_cache_lines():
    """Overwriting a slot (delete -> consolidate -> reuse, or plain insert
    into a fresh slot that a previous epoch's row occupied) must never serve
    the OLD row from the device cache."""
    _, b = _live_pair("int8")
    a, _ = _live_pair("int8")
    _, _, qs, r = _base()
    stream = _clustered(80, seed=11)
    # warm the cache on the initial rows
    _assert_bitwise(a.range(qs, r, cfg=CFG), b.range(qs, r, cfg=CFG))
    # churn the SAME slots repeatedly: insert, delete, re-insert shifted
    for k in range(3):
        ids_a, ids_b = a.insert(stream[:40] + 0.01 * k), \
            b.insert(stream[:40] + 0.01 * k)
        np.testing.assert_array_equal(ids_a, ids_b)
        _assert_bitwise(a.range(qs, r, cfg=CFG), b.range(qs, r, cfg=CFG))
        a.delete(ids_a)
        b.delete(ids_b)
        a.maybe_consolidate()
        b.maybe_consolidate()
        _assert_bitwise(a.range(qs, r, cfg=CFG), b.range(qs, r, cfg=CFG))


def test_checkpoint_store_and_manifest_never_disagree(tmp_path):
    """Crash contract: a torn checkpoint directory is invisible; every
    COMPLETED step's manifest and payload describe the same host store,
    and the restore is a copy-on-write mmap of that payload (writable,
    bitwise-equal, raw rows never copied through HBM)."""
    _, b = _live_pair("int8")
    _, _, qs, r = _base()
    stream = _clustered(100, seed=9)
    cm = CheckpointManager(str(tmp_path), keep=3)

    b.insert(stream[:40])
    b.save(cm, step=1)
    raw1 = b.points.store.to_array().copy()
    b.insert(stream[40:80])
    b.delete(np.arange(10, 30))
    b.save(cm, step=2)
    raw2 = b.points.store.to_array().copy()
    res2 = b.range(qs, r, cfg=CFG)

    # simulate a crash mid-save: a payload-only tmp dir with no manifest
    torn = tmp_path / "step_0000000003.tmp"
    torn.mkdir()
    (torn / "raw.npy").write_bytes(b"\x93NUMPY garbage")
    assert cm.latest_step() == 2  # the torn step does not exist

    for step, raw in ((1, raw1), (2, raw2)):
        man = cm.manifest(step)
        assert "raw" in man["paths"]  # the store's rows are IN the payload
        got = LiveIndex.restore(cm, step=step)
        # manifest extra and the rebuilt tier agree on the static config
        assert man["extra"]["tier"]["cache_rows"] == got.points.cache.capacity
        np.testing.assert_array_equal(got.points.store.to_array(), raw)
    restored = LiveIndex.restore(cm)  # latest == step 2
    _assert_bitwise(res2, restored.range(qs, r, cfg=CFG))
    # CoW mmap backing still takes writes: post-restore churn works and
    # stays bit-identical to the uninterrupted index
    np.testing.assert_array_equal(b.insert(stream[80:]),
                                  restored.insert(stream[80:]))
    _assert_bitwise(b.range(qs, r, cfg=CFG), restored.range(qs, r, cfg=CFG))


# ---------------------------------------------------------------------------
# serving: count op on a tiered engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("continuous", [False, True],
                         ids=["lockstep", "continuous"])
def test_count_op_tiered_server(continuous):
    _, eng_t = _engines("int8")
    _, _, qs, r = _base()
    scfg = RangeConfig(search=dataclasses.replace(CFG.search,
                                                  corpus_dtype="int8"),
                       mode=CFG.mode, result_cap=CFG.result_cap)
    srv = RangeServer(eng_t, scfg,
                      ServerConfig(max_batch=16, continuous=continuous,
                                   lanes=8) if continuous else
                      ServerConfig(max_batch=16))
    qn = np.asarray(qs)
    for i in range(8):
        srv.submit(Request(req_id=i, query=qn[i], radius=r))
        srv.submit(Request(req_id=100 + i, op="count", query=qn[i], radius=r))
    resp = {x.req_id: x for x in srv.run_until_drained()}
    for i in range(8):
        c = resp[100 + i]
        assert c.op == "count" and c.code is None
        assert c.ids.size == 0 and c.dists.size == 0  # count-only payload
        assert c.count == resp[i].count  # same certified post-rerank count
    assert srv.stats["count_requests"] == 8


# ---------------------------------------------------------------------------
# kernel: TPU fetch+rerank emulated on CPU must match the XLA reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_rerank_fetch_kernel_interpret_parity(metric):
    rng = np.random.default_rng(0)
    raw = jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32))
    # 48 pairs: a tile multiple (the planner's pow2 buckets guarantee
    # this), with duplicate ids as dedup's inverse produces
    ids = jnp.asarray(rng.integers(0, 64, 48), jnp.int32)
    qv = jnp.asarray(rng.standard_normal((48, 16)).astype(np.float32))
    ref = fetch_rerank_dists(raw, ids, qv, metric=metric)
    pal = fetch_rerank_dists(raw, ids, qv, metric=metric,
                             use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
