"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, output shapes + no NaNs (brief requirement) —
plus decode-consistency and family-specific behaviour checks."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_arch
from repro.data.graphs import NeighborSampler, make_sbm_graph, range_graph_dataset
from repro.data.lm import LMDataConfig, lm_batch
from repro.data.recsys import RecsysDataConfig, recsys_batch
from repro.models import (
    GCNConfig, decode_step, forward, gcn_batched_graphs, gcn_loss, greedy_token, init_gcn, init_recsys, init_transformer, logits_from_hidden, loss_fn, prefill, recsys_forward, recsys_loss,
)
from repro.optim import AdamWConfig, init_adamw, make_train_step

KEY = jax.random.PRNGKey(0)


def _one_train_step(loss, params, batch):
    step = make_train_step(loss, AdamWConfig(lr=1e-3, warmup_steps=1))
    opt = init_adamw(params, AdamWConfig())
    new_params, opt, metrics = step(params, opt, batch)
    return new_params, metrics


def _lm_batch(cfg, b=2, s=24):
    d = lm_batch(LMDataConfig(vocab=cfg.vocab, seq_len=s, batch=b), 0)
    return {k: jnp.asarray(v) for k, v in d.items()}


def _recsys_batch(cfg, b=16):
    d = recsys_batch(RecsysDataConfig(
        n_dense=cfg.n_dense, n_sparse=cfg.n_sparse, vocab=cfg.vocab, batch=b,
        two_tower=cfg.kind == "two_tower", n_sparse_item=cfg.n_sparse_item), 0)
    return {k: jnp.asarray(v) for k, v in d.items()}


def _gnn_batch(cfg, n=60, e=200):
    g = make_sbm_graph(n, cfg.n_classes, cfg.d_feat, avg_degree=e // n)
    return {"feats": jnp.asarray(g.feats), "edge_src": jnp.asarray(g.edge_src),
            "edge_dst": jnp.asarray(g.edge_dst), "labels": jnp.asarray(g.labels)}


# ---------------------------------------------------------------------------
# The 10 assigned archs: reduced-config smoke (brief deliverable f)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_arch_smoke_train_step(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.reduced()
    if arch.family == "lm":
        params = init_transformer(KEY, cfg)
        loss = functools.partial(loss_fn, cfg=cfg)
        batch = _lm_batch(cfg)
    elif arch.family == "gnn":
        params = init_gcn(KEY, cfg)
        loss = functools.partial(gcn_loss, cfg=cfg)
        batch = _gnn_batch(cfg)
    else:
        params = init_recsys(KEY, cfg)
        loss = functools.partial(recsys_loss, cfg=cfg)
        batch = _recsys_batch(cfg)
    l0, _ = loss(params, batch)
    assert np.isfinite(float(l0)), f"{arch_id}: non-finite loss"
    new_params, metrics = _one_train_step(loss, params, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    for p0, p1 in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert p0.shape == p1.shape
        assert np.isfinite(np.asarray(p1, np.float32)).all()


@pytest.mark.parametrize("arch_id",
                         [a for a in ASSIGNED if REGISTRY[a].family == "lm"])
def test_lm_smoke_forward_shapes(arch_id):
    cfg = get_arch(arch_id).reduced()
    params = init_transformer(KEY, cfg)
    toks = _lm_batch(cfg)["tokens"]
    hidden, _, aux = forward(params, toks, cfg)
    assert hidden.shape == toks.shape + (cfg.d_model,)
    logits = logits_from_hidden(params, hidden, cfg)
    assert logits.shape == toks.shape + (cfg.vocab,)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch_id",
                         [a for a in ASSIGNED if REGISTRY[a].family == "lm"])
def test_lm_decode_matches_teacher_forcing(arch_id):
    cfg = get_arch(arch_id).reduced()
    params = init_transformer(jax.random.PRNGKey(1), cfg)
    toks = _lm_batch(cfg, b=2, s=12)["tokens"]
    lg_p, cache, kvlen = prefill(params, toks, cfg, max_len=16)
    h_full, _, _ = forward(params, toks, cfg)
    lg_full = logits_from_hidden(params, h_full[:, -1:], cfg)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_full),
                               rtol=3e-3, atol=3e-3)
    nt = greedy_token(lg_p)[:, -1:]
    lg_d, _ = decode_step(params, nt, cache, kvlen, cfg)
    toks2 = jnp.concatenate([toks, nt], axis=1)
    h2, _, _ = forward(params, toks2, cfg)
    lg2 = logits_from_hidden(params, h2[:, -1:], cfg)
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg2),
                               rtol=5e-3, atol=5e-3)


def test_lm_loss_masking():
    cfg = get_arch("qwen3-14b").reduced()
    params = init_transformer(KEY, cfg)
    b = _lm_batch(cfg)
    l_full, _ = loss_fn(params, b, cfg)
    b_masked = dict(b, labels=b["labels"].at[:, ::2].set(-1))
    l_half, _ = loss_fn(params, b_masked, cfg)
    assert np.isfinite(float(l_half)) and abs(float(l_half) - float(l_full)) > 1e-6


# ---------------------------------------------------------------------------
# GNN specifics
# ---------------------------------------------------------------------------

def test_gcn_learns_sbm_labels():
    cfg = GCNConfig(n_layers=2, d_feat=16, d_hidden=16, n_classes=4)
    g = make_sbm_graph(300, 4, 16, avg_degree=8, seed=1)
    batch = {"feats": jnp.asarray(g.feats), "edge_src": jnp.asarray(g.edge_src),
             "edge_dst": jnp.asarray(g.edge_dst), "labels": jnp.asarray(g.labels)}
    params = init_gcn(KEY, cfg)
    loss = functools.partial(gcn_loss, cfg=cfg)
    step = make_train_step(loss, AdamWConfig(lr=5e-2, warmup_steps=1,
                                             schedule="constant"))
    opt = init_adamw(params, AdamWConfig())
    accs = []
    for _ in range(40):
        params, opt, m = step(params, opt, batch)
    _, metrics = loss(params, batch)
    assert float(metrics["acc"]) > 0.8


def test_neighbor_sampler_fixed_shapes_and_validity():
    g = make_sbm_graph(500, 4, 8, avg_degree=6)
    s = NeighborSampler(g, fanouts=(5, 3), batch_nodes=16, seed=0)
    b1, b2 = s.sample(), s.sample()
    assert b1.feats.shape == b2.feats.shape
    assert b1.edge_src.shape == b2.edge_src.shape
    ok = b1.edge_src >= 0
    assert ok.any()
    # all edge endpoints reference valid local slots
    n_nodes = (b1.node_ids >= 0).sum()
    assert b1.edge_src[ok].max() < n_nodes
    assert b1.edge_dst[ok].max() < n_nodes
    # sampled-batch training runs
    cfg = GCNConfig(n_layers=2, d_feat=8, d_hidden=16, n_classes=4)
    batch = {"feats": jnp.asarray(b1.feats), "edge_src": jnp.asarray(b1.edge_src),
             "edge_dst": jnp.asarray(b1.edge_dst), "labels": jnp.asarray(b1.labels)}
    l, m = gcn_loss(init_gcn(KEY, cfg), batch, cfg)
    assert np.isfinite(float(l))


def test_range_graph_dataset_uses_engine():
    """DESIGN.md §6: the GNN input graph built by the paper's own engine."""
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((120, 8)).astype(np.float32)
    labels = rng.integers(0, 3, 120)
    g = range_graph_dataset(pts, labels, 3, k=6)
    assert g.n_edges == 120 * 6
    assert g.edge_dst.max() < 120 and g.edge_src.max() < 120


def test_gcn_batched_graphs_shape():
    cfg = GCNConfig(n_layers=2, d_feat=6, d_hidden=8, n_classes=2)
    params = init_gcn(KEY, cfg)
    feats = jax.random.normal(KEY, (4, 10, 6))
    es = jnp.zeros((4, 12), jnp.int32)
    ed = jnp.ones((4, 12), jnp.int32)
    out = gcn_batched_graphs(params, feats, es, ed, cfg)
    assert out.shape == (4, 2)


# ---------------------------------------------------------------------------
# RecSys specifics
# ---------------------------------------------------------------------------

def test_two_tower_loss_decreases():
    cfg = get_arch("two-tower-retrieval").reduced()
    params = init_recsys(KEY, cfg)
    loss = functools.partial(recsys_loss, cfg=cfg)
    step = make_train_step(loss, AdamWConfig(lr=1e-2, warmup_steps=1,
                                             schedule="constant"))
    opt = init_adamw(params, AdamWConfig())
    losses = []
    for i in range(15):
        batch = _recsys_batch(cfg, b=64)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_retrieval_topk_finds_planted_match():
    from repro.models.recsys import retrieval_topk
    q = jnp.zeros((1, 8)).at[0, 0].set(1.0)
    cands = jax.random.normal(KEY, (1000, 8)) * 0.1
    cands = cands.at[123].set(q[0])
    idx, vals = retrieval_topk(q, cands, k=5)
    assert 123 in np.asarray(idx)[0]


@pytest.mark.parametrize("arch_id", ["wide-deep", "dlrm-rm2", "autoint"])
def test_ctr_forward_shapes(arch_id):
    cfg = get_arch(arch_id).reduced()
    params = init_recsys(KEY, cfg)
    b = _recsys_batch(cfg, b=8)
    b.pop("label")
    logit = recsys_forward(params, b, cfg)
    assert logit.shape == (8,)
    assert np.isfinite(np.asarray(logit)).all()


# ---------------------------------------------------------------------------
# registry completeness (deliverable f)
# ---------------------------------------------------------------------------

def test_registry_covers_40_cells():
    assert len(ASSIGNED) == 10
    total = sum(len(REGISTRY[a].shapes) for a in ASSIGNED)
    assert total == 40
    for a in ASSIGNED:
        arch = REGISTRY[a]
        assert arch.reduced is not None
        assert arch.technique_note, f"{a} missing technique applicability note"
        assert arch.source


def test_exact_published_geometries():
    g = REGISTRY["gemma3-27b"].model_cfg
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv, g.d_ff, g.vocab) == \
        (62, 5376, 32, 16, 21504, 262144)
    q = REGISTRY["qwen3-14b"].model_cfg
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv, q.d_ff, q.vocab) == \
        (40, 5120, 40, 8, 17408, 151936)
    s = REGISTRY["starcoder2-7b"].model_cfg
    assert (s.n_layers, s.d_model, s.n_heads, s.n_kv, s.d_ff, s.vocab) == \
        (32, 4608, 36, 4, 18432, 49152)
    d = REGISTRY["deepseek-v2-236b"].model_cfg
    assert (d.n_layers, d.d_model, d.n_heads, d.kv_lora, d.n_experts,
            d.top_k, d.n_shared, d.d_expert, d.vocab) == \
        (60, 5120, 128, 512, 160, 6, 2, 1536, 102400)
    m = REGISTRY["qwen2-moe-a2.7b"].model_cfg
    assert (m.n_layers, m.d_model, m.n_heads, m.n_experts, m.top_k,
            m.n_shared, m.d_expert, m.vocab) == \
        (24, 2048, 16, 60, 4, 4, 1408, 151936)
    gc = REGISTRY["gcn-cora"].model_cfg
    assert (gc.n_layers, gc.d_hidden) == (2, 16)
    dl = REGISTRY["dlrm-rm2"].model_cfg
    assert (dl.n_dense, dl.n_sparse, dl.d_embed) == (13, 26, 64)
    assert dl.bot_mlp_dims == (512, 256, 64) and dl.mlp_dims == (512, 512, 256)
    ai = REGISTRY["autoint"].model_cfg
    assert (ai.n_sparse, ai.d_embed, ai.attn_layers, ai.attn_heads, ai.d_attn) == \
        (39, 16, 3, 2, 32)
